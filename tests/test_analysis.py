"""Unit suite for the static-analysis engine, rules, and runtime gates.

Every rule gets a true-positive and a true-negative fixture snippet
(written under a path that puts it in the rule's module scope), plus a
suppression-honoring case; the framework pieces (suppression parsing,
baseline diffing, reporters) and the runtime watches (CompileWatch /
SyncWatch) are exercised directly.  The StampPattern / SolveSignature
cache-key stability contract is regression-tested with the compile
counter: equal-but-distinct keys must not retrigger lowering.
"""

import json
import textwrap

import numpy as np
import pytest

from repro.analysis import (
    ALL_RULES,
    Analyzer,
    CompileWatch,
    Finding,
    SyncWatch,
    diff_baseline,
    human_report,
    is_suppressed,
    json_report,
    load_baseline,
    parse_suppressions,
    sync_scope,
    write_baseline,
)
from repro.analysis.runtime import _SCOPE_STACK


def run_on(tmp_path, rel_path, source, rules=ALL_RULES, config=None):
    """Analyze one fixture snippet at a repo-relative-like path."""
    f = tmp_path / rel_path
    f.parent.mkdir(parents=True, exist_ok=True)
    f.write_text(textwrap.dedent(source))
    return Analyzer(rules, config).run([f], root=tmp_path)


def rules_of(findings):
    return sorted({f.rule for f in findings})


# ------------------------------------------------------------- suppressions


def test_suppression_parsing_forms():
    src = (
        "x = 1  # repro: ignore\n"
        "y = 2  # repro: ignore[rule-a, rule-b]\n"
        "# repro: ignore[rule-c]\n"
        "z = 3\n"
        "w = 4\n"
    )
    sup = parse_suppressions(src)
    assert sup[1] == frozenset({"*"})
    assert sup[2] == frozenset({"rule-a", "rule-b"})
    # a comment-only line covers itself and the next line
    assert sup[3] == frozenset({"rule-c"})
    assert sup[4] == frozenset({"rule-c"})
    assert 5 not in sup


def test_is_suppressed_matches_rule_and_wildcard():
    f = Finding(rule="r", path="p", line=3, col=0,
                severity="error", message="m")
    assert is_suppressed(f, {3: frozenset({"r"})})
    assert is_suppressed(f, {3: frozenset({"*"})})
    assert not is_suppressed(f, {3: frozenset({"other"})})
    assert not is_suppressed(f, {4: frozenset({"r"})})


# -------------------------------------------------------- host-sync-in-hot-path

HOT_LOOP_BAD = """
    import numpy as np

    class S:
        def drain(self):
            for flight in self.inflight:
                x = np.asarray(flight.result)
                v = flight.res.item()
                t = float(flight.elapsed)
"""

HOT_LOOP_OK = """
    import numpy as np

    class S:
        def drain(self):
            for flight in self.inflight:
                self.pending.append(flight)

        def _unpack(self):
            # not a hot function: materialization is fine here
            return np.asarray(self.batch.x)
"""


def test_host_sync_flags_sync_calls_in_hot_loop(tmp_path):
    found = run_on(tmp_path, "serving/loop.py", HOT_LOOP_BAD)
    assert rules_of(found) == ["host-sync-in-hot-path"]
    assert len(found) == 3          # asarray + .item() + float()


def test_host_sync_ignores_cold_paths_and_other_modules(tmp_path):
    assert run_on(tmp_path, "serving/loop.py", HOT_LOOP_OK) == []
    # same bad code outside serving/ is out of scope
    assert run_on(tmp_path, "core/loop.py", HOT_LOOP_BAD) == []


def test_host_sync_suppression_honored(tmp_path):
    src = """
    import numpy as np

    class S:
        def drain(self):
            for f in self.inflight:
                x = np.asarray(f.r)  # repro: ignore[host-sync-in-hot-path]
    """
    assert run_on(tmp_path, "serving/loop.py", src) == []


# ------------------------------------------------------------ recompile-hazard

JIT_IN_BODY = """
    import jax

    def solve(m):
        f = jax.jit(lambda x: x @ x)
        return f(m)
"""

JIT_AT_MODULE = """
    import functools
    import jax

    @functools.partial(jax.jit, static_argnames=("block",))
    def kernel(x, block=128):
        return x

    _solver = jax.jit(lambda m: m)

    class Engine:
        def __init__(self):
            self._step = jax.jit(lambda c: c)
"""

UNHASHABLE_STATIC = """
    import functools
    import jax

    @functools.partial(jax.jit, static_argnames=("shape",))
    def pad(x, shape=[1, 2]):
        return x
"""

TRACED_BRANCH = """
    import jax

    @jax.jit
    def step(x):
        if float(x[0]) > 0:
            return x
        return -x
"""


def test_recompile_flags_jit_in_function_body(tmp_path):
    found = run_on(tmp_path, "kernels/k.py", JIT_IN_BODY)
    assert rules_of(found) == ["recompile-hazard"]


def test_recompile_allows_module_scope_decorators_and_init(tmp_path):
    # the decorator's own partial(jax.jit, ...) call must NOT count as
    # a call "inside" the function it decorates
    assert run_on(tmp_path, "kernels/k.py", JIT_AT_MODULE) == []


def test_recompile_flags_unhashable_static_default(tmp_path):
    found = run_on(tmp_path, "kernels/k.py", UNHASHABLE_STATIC)
    assert rules_of(found) == ["recompile-hazard"]
    assert "unhashable" in found[0].message


def test_recompile_flags_traced_value_branch(tmp_path):
    found = run_on(tmp_path, "kernels/k.py", TRACED_BRANCH)
    assert rules_of(found) == ["recompile-hazard"]
    assert "branch test" in found[0].message


# -------------------------------------------------------------- dtype-contract

BF16_ESCAPE = """
    import jax.numpy as jnp

    def prepare(m):
        return jnp.asarray(m).astype("bfloat16")
"""

BF16_IN_BOUNDARY = """
    import jax.numpy as jnp

    def euler_settle_batch(m):
        return jnp.asarray(m).astype("bfloat16")
"""

X64_NARROWING = """
    import numpy as np

    def refine(r):
        return np.zeros(3, dtype=np.float32) + r.astype("float32")
"""


def test_dtype_flags_bf16_escape_outside_kernels(tmp_path):
    found = run_on(tmp_path, "serving/svc.py", BF16_ESCAPE)
    assert rules_of(found) == ["dtype-contract"]


def test_dtype_allows_bf16_inside_boundary(tmp_path):
    # the kernels/ module and the declared boundary functions are the
    # sanctioned low-precision zone
    assert run_on(tmp_path, "kernels/sweep.py", BF16_ESCAPE) == []
    assert run_on(tmp_path, "core/engine.py", BF16_IN_BOUNDARY) == []


def test_dtype_flags_narrowing_in_x64_modules_only(tmp_path):
    found = run_on(tmp_path, "core/refine.py", X64_NARROWING)
    assert rules_of(found) == ["dtype-contract"]
    assert len(found) == 2          # dtype= construction + astype
    # the same narrowing outside the strict-x64 module set is fine
    assert run_on(tmp_path, "serving/svc.py", X64_NARROWING) == []


# ---------------------------------------------------------- donation-after-use

DONATE_THEN_READ = """
    import jax

    _f = jax.jit(lambda m, c: m + c, donate_argnums=(0,))

    def solve(m, c):
        y = _f(m, c)
        return y + m.sum()
"""

DONATE_IN_RETURN = """
    import jax

    _f = jax.jit(lambda m, c: m + c, donate_argnums=(0, 1))

    def solve(m, c, use_donation):
        if use_donation:
            return _f(m, c)
        # this branch only runs when the donating call did not
        return m @ c
"""

DONATE_THEN_REBIND = """
    import jax

    _f = jax.jit(lambda m: m * 2, donate_argnums=(0,))

    def solve(m):
        y = _f(m)
        m = y + 1
        return m
"""


def test_donation_flags_read_after_donating_call(tmp_path):
    found = run_on(tmp_path, "core/s.py", DONATE_THEN_READ)
    assert rules_of(found) == ["donation-after-use"]
    assert "'m'" in found[0].message


def test_donation_allows_return_position_and_rebinding(tmp_path):
    assert run_on(tmp_path, "core/s.py", DONATE_IN_RETURN) == []
    assert run_on(tmp_path, "core/s.py", DONATE_THEN_REBIND) == []


# -------------------------------------------------------- unlocked-shared-state

UNLOCKED = """
    class AdmissionQueue:
        def __init__(self):
            self._items = []

        def push(self, item):
            self._items.append(item)
"""

LOCKED = """
    import threading

    class AdmissionQueue:
        def __init__(self):
            self._items = []
            self._lock = threading.Lock()

        def push(self, item):
            with self._lock:
                self._items.append(item)

        def __len__(self):
            return len(self._items)
"""


def test_unlocked_flags_mutation_outside_lock(tmp_path):
    found = run_on(tmp_path, "serving/q.py", UNLOCKED)
    assert rules_of(found) == ["unlocked-shared-state"]


def test_unlocked_accepts_lock_and_exempts_init(tmp_path):
    assert run_on(tmp_path, "serving/q.py", LOCKED) == []
    # classes outside the configured shared-state set are not checked
    other = UNLOCKED.replace("AdmissionQueue", "LocalScratch")
    assert run_on(tmp_path, "serving/q.py", other) == []


# --------------------------------------------------- blocking-call-in-stream-loop

BLOCKING = """
    class S:
        def step(self):
            import time
            time.sleep(0.1)
"""

BLOCKING_SUPPRESSED = """
    import time

    class S:
        def step(self):
            # injected-slow chaos fault: the stall is the point
            time.sleep(0.1)  # repro: ignore[blocking-call-in-stream-loop]
"""


def test_blocking_flags_import_and_sleep_in_stream_code(tmp_path):
    found = run_on(tmp_path, "serving/e.py", BLOCKING)
    assert rules_of(found) == ["blocking-call-in-stream-loop"]
    assert len(found) == 2          # the import and the sleep


def test_blocking_suppression_and_cold_functions(tmp_path):
    assert run_on(tmp_path, "serving/e.py", BLOCKING_SUPPRESSED) == []
    cold = BLOCKING.replace("def step", "def build_report")
    assert run_on(tmp_path, "serving/e.py", cold) == []


# ------------------------------------------------------------- swallowed-error

SWALLOWED = """
    def deliver(t):
        try:
            t.send()
        except Exception:
            pass

    def harvest(t):
        try:
            t.wait()
        except:
            return None
"""

HANDLED = """
    def deliver(t, out):
        try:
            t.send()
        except Exception as exc:
            out[t.rid] = make_error(exc)

    def narrow(t):
        try:
            t.wait()
        except TimeoutError:
            pass
"""


def test_swallowed_flags_bare_and_pass_body_handlers(tmp_path):
    found = run_on(tmp_path, "serving/d.py", SWALLOWED)
    assert rules_of(found) == ["swallowed-error"]
    assert len(found) == 2


def test_swallowed_accepts_structured_delivery_and_narrow_types(tmp_path):
    assert run_on(tmp_path, "serving/d.py", HANDLED) == []


# ----------------------------------------------------------- analyzer plumbing


def test_analyzer_config_disables_and_reoptions_rules(tmp_path):
    config = {"swallowed-error": {"enabled": False}}
    assert run_on(tmp_path, "serving/d.py", SWALLOWED,
                  config=config) == []
    # option override: a different hot-function set
    config = {"host-sync-in-hot-path": {"hot_functions": ("other",)}}
    assert run_on(tmp_path, "serving/loop.py", HOT_LOOP_BAD,
                  config=config) == []


def test_analyzer_reports_parse_errors_as_findings(tmp_path):
    found = run_on(tmp_path, "serving/broken.py", "def f(:\n")
    assert rules_of(found) == ["parse-error"]


def test_repo_source_tree_is_clean_against_committed_baseline():
    """The tree must analyze clean — the same check CI enforces."""
    from pathlib import Path

    from repro.analysis.__main__ import DEFAULT_BASELINE

    root = Path(__file__).resolve().parents[1]
    findings = Analyzer(ALL_RULES).run([root / "src"], root=root)
    new, _stale = diff_baseline(findings, load_baseline(DEFAULT_BASELINE))
    assert new == [], human_report(new)


# ----------------------------------------------------------- baseline diffing


def F(rule="r", path="p.py", line=1, message="m"):
    return Finding(rule=rule, path=path, line=line, col=0,
                   severity="error", message=message)


def test_diff_baseline_absorbs_counts_and_reports_overflow():
    entries = [{"rule": "r", "path": "p.py", "message": "m", "count": 2}]
    new, stale = diff_baseline([F(line=1), F(line=9), F(line=30)], entries)
    assert len(new) == 1 and stale == []        # third one overflows
    new, stale = diff_baseline([F(line=5)], entries)
    assert new == []
    assert stale == [{"rule": "r", "path": "p.py", "message": "m",
                      "count": 1}]


def test_baseline_roundtrip_preserves_why(tmp_path):
    path = tmp_path / "baseline.json"
    write_baseline([F(), F(line=2)], path)
    entries = load_baseline(path)
    assert entries[0]["count"] == 2
    assert entries[0]["why"] == "TODO: justify"
    entries[0]["why"] = "legacy exception"
    write_baseline([F()], path, previous=entries)
    assert load_baseline(path)[0]["why"] == "legacy exception"


def test_baseline_version_mismatch_raises(tmp_path):
    path = tmp_path / "baseline.json"
    path.write_text(json.dumps({"version": 99, "entries": []}))
    with pytest.raises(ValueError):
        load_baseline(path)


def test_reporters():
    out = human_report([F()])
    assert "p.py:1:1" in out and "1 finding(s): 1 error" in out
    assert human_report([]) == "clean: no findings"
    data = json.loads(json_report([F(), F()]))
    assert data["total"] == 2 and data["counts"] == {"r": 2}


# -------------------------------------------------------------- runtime gates


def test_compile_watch_counts_fresh_lowering_only():
    import jax
    import jax.numpy as jnp

    f = jax.jit(lambda x: x * 2 + 1)
    x = jnp.arange(4.0)
    with CompileWatch() as w1:
        f(x).block_until_ready()
    assert w1.count == 1
    assert w1.host_callback_findings() == []
    with CompileWatch(capture_hlo=False) as w2:
        f(x).block_until_ready()        # cache hit: no new lowering
    assert w2.count == 0


def test_compile_watch_rejects_reentry():
    with CompileWatch():
        with pytest.raises(RuntimeError):
            CompileWatch().__enter__()


def test_sync_watch_attributes_syncs_to_scope():
    import jax.numpy as jnp

    y = jnp.arange(3.0)
    with SyncWatch() as watch:
        np.asarray(y)                       # ambient
        with sync_scope("harvest"):
            xs = np.asarray(y)
            float(xs[0])                    # numpy operand: not counted
        np.asarray(np.arange(3.0))          # numpy operand: not counted
    assert watch.counts == {"ambient": 1, "harvest": 1}
    assert watch.total() == 2
    assert watch.total("harvest") == 1
    # patches restored, scope stack balanced
    assert _SCOPE_STACK == ["ambient"]
    with sync_scope("x"):
        assert _SCOPE_STACK[-1] == "x"
    assert _SCOPE_STACK == ["ambient"]


# ----------------------------------------------------- cache-key stability


def _patterns():
    from repro.core.engine import _build_pattern

    mk = lambda g: _build_pattern(
        "proposed", 12, 6, np.arange(6), np.arange(6) + 6,
        np.arange(g), 2, True,
    )
    return mk(2), mk(2), mk(3)


def test_stamp_pattern_hash_eq_contract():
    p1, p2, p3 = _patterns()
    assert p1 == p2 and p1 is not p2
    assert hash(p1) == hash(p2)
    assert p1 != p3 and p1 != "not a pattern"
    assert len({p1, p2, p3}) == 2


def test_equal_patterns_share_one_jit_cache_entry():
    """Equal-but-distinct StampPatterns as static args must not
    retrigger lowering — the regression the generated dataclass
    ``__hash__`` (TypeError) made impossible to even express."""
    import functools

    import jax

    p1, p2, p3 = _patterns()

    @functools.partial(jax.jit, static_argnums=(1,))
    def f(x, pat):
        return x * pat.n_states

    x = np.arange(3.0)
    with CompileWatch(capture_hlo=False) as warm:
        f(x, p1).block_until_ready()
    assert warm.count == 1
    with CompileWatch(capture_hlo=False) as again:
        f(x, p2).block_until_ready()    # equal pattern: cache hit
    assert again.count == 0
    with CompileWatch(capture_hlo=False) as differ:
        f(x, p3).block_until_ready()    # different pattern: recompile
    assert differ.count == 1


def test_solve_signature_cache_key_stability():
    from repro.core.operating_point import NonIdealities
    from repro.core.specs import OPAMPS
    from repro.serving.solve_service import SolveSignature

    mk = lambda: SolveSignature(
        method="analog_2n", opamp=OPAMPS["AD712"],
        nonideal=NonIdealities(), compute_settling=True,
    ).normalized()
    s1, s2 = mk(), mk()
    assert s1 == s2 and hash(s1) == hash(s2)
    # every field of the bucket key must stay hashable — a single
    # unhashable field silently breaks dict bucketing at submit time
    assert {s1: "bucket"}[s2] == "bucket"
