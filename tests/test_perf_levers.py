"""Tests for the §Perf-adopted optimization levers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.distributed.rules import apply_attn_batch_layout, make_rules
from repro.distributed.sharding import boundary_pin, use_rules
from repro.models.moe import moe_ffn, moe_ffn_grouped


# ------------------------------------------------ attention batch layout
def test_attn_layout_engages_for_non_dividing_heads():
    cfg = get_config("yi_34b")                 # 56 heads
    rules = make_rules(cfg)
    out = apply_attn_batch_layout(rules, cfg, 256, multi_pod=False)
    assert out["attn_batch"] == ("data", "model")
    assert out["head_dim"] is None


def test_attn_layout_noop_for_heads_mode():
    cfg = get_config("qwen3_8b")               # 32 heads
    rules = make_rules(cfg)
    out = apply_attn_batch_layout(rules, cfg, 256, multi_pod=False)
    assert out["attn_batch"] == out["batch"]
    assert out["q_heads"] == "model"


def test_attn_layout_noop_for_small_batch():
    cfg = get_config("yi_34b")
    rules = make_rules(cfg)
    out = apply_attn_batch_layout(rules, cfg, 32, multi_pod=False)
    assert out["attn_batch"] == out["batch"]   # 32 < 256: no-op


def test_attn_layout_noop_multi_pod():
    cfg = get_config("yi_34b")
    rules = make_rules(cfg, multi_pod=True)
    out = apply_attn_batch_layout(rules, cfg, 256, multi_pod=True)
    assert out["attn_batch"] == out["batch"]


# ----------------------------------------------------------- boundary pin
def test_boundary_pin_is_noop_when_layouts_match():
    """Heads-mode archs must not pay the redundant constraint (P2b)."""
    x = jnp.ones((4, 8))
    rules = {"batch": "data", "attn_batch": "data"}
    with use_rules(rules):
        y = boundary_pin(x, ("batch", None))
    assert y is x      # literally untouched — no constraint op traced


def test_boundary_pin_applies_on_mismatch(monkeypatch):
    """On layout mismatch the pin must reach with_sharding_constraint."""
    calls = []
    monkeypatch.setattr(
        jax.lax, "with_sharding_constraint",
        lambda x, spec: calls.append(spec) or x)
    x = jnp.ones((4, 8))
    rules = {"batch": "data", "attn_batch": ("data", "model")}
    with use_rules(rules):
        boundary_pin(x, ("batch", None))
    assert len(calls) == 1
    assert calls[0] == jax.sharding.PartitionSpec("data", None)


# ------------------------------------------------------ grouped dispatch
def test_grouped_matches_flat_when_balanced():
    """With generous capacity, group-local dispatch must reproduce the
    flat dispatch exactly (routing decisions are per-token)."""
    rng = np.random.default_rng(0)
    n, d, f, e, k, g = 64, 16, 32, 4, 2, 4
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    p = {
        "w_router": jnp.asarray(rng.standard_normal((d, e)) * 0.1, jnp.float32),
        "w_gate": jnp.asarray(rng.standard_normal((e, d, f)) * 0.05, jnp.float32),
        "w_up": jnp.asarray(rng.standard_normal((e, d, f)) * 0.05, jnp.float32),
        "w_down": jnp.asarray(rng.standard_normal((e, f, d)) * 0.05, jnp.float32),
    }
    y_flat, _ = moe_ffn(x, p, n_experts=e, top_k=k, capacity_factor=8.0)
    y_grp, _ = moe_ffn_grouped(
        x, p, n_experts=e, top_k=k, groups=g, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(y_grp), np.asarray(y_flat),
                               rtol=2e-5, atol=2e-5)


def test_grouped_dispatch_in_model():
    """granite-moe smoke config with dispatch groups runs + is finite."""
    import dataclasses

    from repro.models.model import forward_train, init_params

    cfg = dataclasses.replace(
        get_smoke_config("granite_moe_1b_a400m"), dispatch_groups=4)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.zeros((2, 32), jnp.int32) + 7
    logits, aux = forward_train(params, {"tokens": toks, "targets": toks}, cfg)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_adopted_configs():
    assert get_config("granite_moe_1b_a400m").dispatch_groups == 16
    assert get_config("mixtral_8x22b").dispatch_groups == 16
