"""Training loop behaviour: loss decreases, optimizers, AnalogNewton
(the paper's solver inside the optimizer), compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.distributed.compression import compress_int8, init_error_state
from repro.optim.adamw import adamw, apply_updates
from repro.optim.analog_newton import (
    AnalogNewtonConfig,
    analog_newton,
    refresh_preconditioner,
)
from repro.optim.schedule import cosine_schedule
from repro.training.loss import cross_entropy_loss
from repro.training.step import init_train_state, make_train_step


def test_loss_masking_and_padded_vocab():
    b, s, vp, v = 2, 8, 512 + 256, 500
    logits = jnp.zeros((b, s, vp))
    targets = jnp.full((b, s), 3, jnp.int32)
    loss, metrics = cross_entropy_loss(logits, targets, v)
    # uniform over the REAL vocab only
    np.testing.assert_allclose(float(metrics["ce"]), np.log(v), rtol=1e-5)
    # ignore ids drop out of the denominator
    targets2 = targets.at[:, :4].set(-1)
    _, m2 = cross_entropy_loss(logits, targets2, v)
    assert float(m2["tokens"]) == b * s / 2


def test_adamw_optimizes_quadratic():
    opt = adamw(0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        upd, state = opt.update(grads, state, params)
        params = apply_updates(params, upd)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup_steps=10, total_steps=100, min_ratio=0.1)
    assert float(lr(jnp.asarray(5))) < 1.0
    np.testing.assert_allclose(float(lr(jnp.asarray(10))), 1.0, rtol=1e-5)
    assert float(lr(jnp.asarray(100))) < 0.15


def test_training_reduces_loss():
    """30 steps on the structured synthetic stream must cut the loss."""
    from repro.data.tokens import SyntheticTokens

    cfg = get_smoke_config("qwen3_8b")
    opt = adamw(3e-3)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, opt))
    data = SyntheticTokens(vocab=cfg.vocab, seq_len=64, batch_size=8, seed=0)
    losses = []
    for _ in range(30):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    data.close()
    assert losses[-1] < losses[0] - 0.2, (losses[0], losses[-1])


@pytest.mark.parametrize("backend", ["cholesky", "analog_2n", "cg"])
def test_analog_newton_refresh_backends(backend):
    """Preconditioner refresh through each solver backend produces the
    correct block inverses (the analog path uses the full circuit)."""
    cfg = AnalogNewtonConfig(block=8, min_dim=8, backend=backend, damping=1e-6)
    opt = analog_newton(1e-2, cfg)
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)}
    state = opt.init(params)
    # feed a few gradient steps to accumulate covariance
    for i in range(5):
        g = {"w": jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)}
        _, state = opt.update(g, state, params)
    state = refresh_preconditioner(state, cfg)
    cov = np.asarray(state["cov"]["w"][0], np.float64)
    damp = cfg.damping * max(np.trace(cov) / cfg.block, 1e-30)
    want = np.linalg.inv(cov + damp * np.eye(cfg.block))
    got = np.asarray(state["pinv"]["w"][0], np.float64)
    rel = np.abs(got - want).max() / np.abs(want).max()
    assert rel < 2e-2, rel


def test_analog_newton_optimizes():
    """AnalogNewton with circuit-refreshed preconditioner reduces a
    correlated least-squares objective."""
    rng = np.random.default_rng(1)
    n, m = 32, 16
    a_data = rng.standard_normal((64, n)) @ np.diag(rng.uniform(0.2, 3.0, n))
    w_true = rng.standard_normal((n, m))
    y = a_data @ w_true
    params = {"w": jnp.asarray(0.1 * rng.standard_normal((n, m)),
                               jnp.float32)}

    cfg = AnalogNewtonConfig(block=16, min_dim=8, backend="analog_2n",
                             refresh_every=5, damping=1e-3)
    # LAMB trust ratio: lr is the per-step relative move; 0.3 descends
    # fast without oscillating in 25 steps
    opt = analog_newton(0.3, cfg)
    state = opt.init(params)

    def loss_fn(p):
        r = jnp.asarray(a_data, jnp.float32) @ p["w"] - jnp.asarray(y, jnp.float32)
        return jnp.mean(r * r)

    losses = []
    for i in range(25):
        g = jax.grad(loss_fn)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
        if (i + 1) % cfg.refresh_every == 0:
            state = refresh_preconditioner(state, cfg)
        losses.append(float(loss_fn(params)))
    assert losses[-1] < 0.75 * losses[0], (losses[0], losses[-1])


def test_compression_error_feedback():
    """int8 EF: single-step error is bounded; residual feedback keeps the
    accumulated bias near zero over repeated identical gradients."""
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
    err = init_error_state(g)
    total = jnp.zeros_like(g["w"])
    for _ in range(50):
        gc, err = compress_int8(g, err)
        total = total + gc["w"]
    # mean of dequantized gradients converges to the true gradient
    np.testing.assert_allclose(
        np.asarray(total / 50), np.asarray(g["w"]), atol=2e-2)
