"""Serving-layer suite for PR 8: the multi-round session ticket kind
and the settle submit/wait split.

Contracts under test:

* ``PendingBatchSolve`` analog handles are two-phase: ``wait_dc()``
  harvests the device DC phase, ``wait()`` composes the deferred
  settle sweep + fallback on top — and the composition equals the
  one-shot ``solve_batch`` result exactly.
* The service releases a stream slot at DC harvest (``finishing``
  queue) and runs settle/fallback afterwards — accounted in
  ``stats()['settle_finish_s']`` — without losing delivery parity.
* :class:`SolveSession` satisfies the ``rounds=`` protocol of
  :mod:`repro.optim.batched_newton`: a Newton run whose every round
  rides the service's bucketed pipelines matches the direct batched
  run, reuses ONE stamp pattern across rounds, preserves interleaved
  one-shot traffic, reports terminal per-ticket failures as
  :class:`SessionRoundError` with partial results, and recovers
  injected mid-loop device faults without perturbing the iterates.
* A mixed-grid FEM mesh stream served end-to-end keeps 1e-9 parity
  with direct solves.
"""

import numpy as np
import pytest

from repro.core.solver import solve, solve_batch, solve_batch_submit
from repro.data.spd import random_rhs_from_solution, random_spd
from repro.optim.batched_newton import BatchedNewtonConfig, newton_batch
from repro.serving import SessionRoundError, SolveService
from repro.serving.faults import FaultInjector, FaultPlan, SolveError

PARITY_ATOL = 1e-9


def _systems(bsz, n, seed=0):
    rng = np.random.default_rng(seed)
    a = np.stack([random_spd(rng, n) for _ in range(bsz)])
    xb = [random_rhs_from_solution(rng, a[k]) for k in range(bsz)]
    return a, np.stack([x for x, _ in xb]), np.stack([b for _, b in xb])


# --------------------------------------------------- two-phase handles
def test_analog_pending_is_split_and_composes_to_solve_batch():
    a, _, b = _systems(3, 5)
    ref = solve_batch(a, b, method="analog_2n", compute_settling=True)
    pending = solve_batch_submit(
        a, b, method="analog_2n", compute_settling=True
    )
    assert pending.split
    dc = pending.wait_dc()                 # device phase only
    assert dc.x.shape == b.shape
    assert dc.settle_time is None and "settle_method" not in dc.info
    full = pending.wait()                  # + settle sweep + fallback
    assert np.array_equal(full.x, ref.x)
    assert full.settle_time is not None and "settle_method" in full.info
    # the finish phase completes the DC batch in place; afterwards both
    # views are idempotent and return the final batch
    assert full is dc
    assert pending.wait() is full and pending.wait_dc() is full


def test_digital_pending_is_single_phase():
    a, _, b = _systems(2, 4, seed=1)
    pending = solve_batch_submit(a, b, method="cholesky")
    assert not pending.split
    assert pending.wait_dc() is pending.wait()


def test_wait_without_wait_dc_still_runs_both_phases():
    a, _, b = _systems(2, 4, seed=2)
    ref = solve_batch(a, b, method="analog_2n")
    pending = solve_batch_submit(a, b, method="analog_2n")
    assert np.array_equal(pending.wait().x, ref.x)


def test_injected_nonfinite_lands_after_the_finish_phase():
    """The chaos injector must corrupt the *delivered* batch on a split
    handle — wait_dc() stays clean, wait() carries the NaN (so the
    fallback cannot repair it and the service sees the corruption)."""
    a, _, b = _systems(2, 4, seed=3)
    pending = solve_batch_submit(a, b, method="analog_2n")
    inj = FaultInjector(FaultPlan(schedule=((0, "nonfinite"),)))
    inj.arm(pending, inj.draw())
    assert np.isfinite(pending.wait_dc().x).all()
    assert np.isnan(pending.wait().x[:, 0]).all()


# ------------------------------------------------- settle split in the service
def test_service_settle_split_accounts_and_keeps_parity():
    svc = SolveService(batch_slots=2)
    a, _, b = _systems(6, 5, seed=4)
    # half the stream requests the settle sweep: those micro-batches
    # must release their stream slot at DC harvest and finish later
    rids = [
        svc.submit(a[k], b[k], method="analog_2n",
                   compute_settling=(k % 2 == 0))
        for k in range(6)
    ]
    out = svc.drain()
    for k, rid in enumerate(rids):
        ref = solve(a[k], b[k], method="analog_2n")
        assert np.abs(out[rid].x - ref.x).max() <= PARITY_ATOL
        if k % 2 == 0:
            assert out[rid].settle_time is not None
    st = svc.stats
    # the settle/fallback work ran in deferred finish phases, after
    # each flight's stream slot was already released
    assert st["settle_finish_s"] > 0.0
    assert st["errors"] == {k: 0 for k in st["errors"]}


# ----------------------------------------------------------- session rounds
def test_session_round_validates_shapes():
    svc = SolveService(batch_slots=2)
    sess = svc.session(method="cholesky")
    with pytest.raises(ValueError, match="expected"):
        sess.solve_round(np.eye(4), np.ones(4))
    with pytest.raises(ValueError, match="expected"):
        sess.solve_round(np.ones((2, 4, 4)), np.ones((3, 4)))


def test_session_round_parity_and_counters():
    svc = SolveService(batch_slots=4)
    sess = svc.session(method="analog_2n")
    a, _, b = _systems(4, 5, seed=5)
    x = sess.solve_round(a, b)
    for k in range(4):
        ref = solve(a[k], b[k], method="analog_2n")
        assert np.abs(x[k] - ref.x).max() <= PARITY_ATOL
    assert sess.rounds == sess.solve_rounds == 1
    assert sess.systems == 4


def test_session_newton_matches_direct_batched_run():
    """The tentpole end-to-end: a Newton client whose rounds ride the
    service's bucketed pipelines converges identically to the direct
    solve_batch executor, on ONE pattern across all rounds."""
    rng = np.random.default_rng(6)
    bsz, n = 3, 5
    t = rng.normal(size=(bsz, n))
    m = rng.normal(size=(bsz, n, n)) / np.sqrt(n)
    q = 0.5 * np.einsum("bij,bkj->bik", m, m) + np.eye(n)
    eye = np.eye(n)

    def grad_hess(x):
        d = x - t
        return (
            np.einsum("bij,bj->bi", q, d) + d ** 3,
            q + (3.0 * d ** 2)[:, :, None] * eye,
        )

    cfg = BatchedNewtonConfig(method="analog_2n", tol=1e-9, max_iter=30)
    tr_direct = newton_batch(grad_hess, np.zeros((bsz, n)), cfg)

    svc = SolveService(batch_slots=4)
    sess = svc.session(method="analog_2n")
    tr_svc = newton_batch(grad_hess, np.zeros((bsz, n)), cfg, rounds=sess)

    assert tr_svc.converged.all()
    assert np.array_equal(tr_svc.iterations, tr_direct.iterations)
    assert np.abs(tr_svc.x - tr_direct.x).max() <= 1e-7
    assert tr_svc.iterations.max() >= 3          # genuinely multi-round
    assert tr_svc.solve_rounds == tr_svc.iterations.max()
    # one sparsity class across every round -> one pattern derivation
    assert sess.pattern_derivations == 1


def test_session_preserves_interleaved_foreign_traffic():
    svc = SolveService(batch_slots=4)
    a1, _, b1 = _systems(1, 5, seed=7)
    foreign = svc.submit(a1[0], b1[0], method="cholesky")
    sess = svc.session(method="cholesky")
    a, _, b = _systems(3, 5, seed=8)
    x = sess.solve_round(a, b)
    assert np.isfinite(x).all()
    # the round's drain answered the one-shot ticket too; the session
    # parks it instead of dropping it
    assert foreign in sess.other_results
    ref = np.linalg.solve(a1[0], b1[0])
    assert np.abs(sess.other_results[foreign].x - ref).max() <= PARITY_ATOL


def test_session_round_error_carries_partial_solutions():
    svc = SolveService(batch_slots=4)
    sess = svc.session(method="analog_2n")
    a, _, b = _systems(3, 5, seed=9)
    a[1, 0, 0] = np.nan                    # one poisoned system
    with pytest.raises(SessionRoundError) as ei:
        sess.solve_round(a, b)
    err = ei.value
    assert err.round_index == 0
    assert set(err.errors) == {1}
    assert isinstance(err.errors[1], SolveError)
    assert np.isnan(err.x[1]).all()
    for k in (0, 2):                       # healthy rows still delivered
        ref = solve(a[k], b[k], method="analog_2n")
        assert np.abs(err.x[k] - ref.x).max() <= PARITY_ATOL
    assert sess.rounds == 1                # the round completed (failed)


def test_session_newton_recovers_injected_midloop_device_fault():
    """A device fault on a mid-loop round dispatch is retried/bisected
    by the service invisibly to the Newton client: zero terminal
    errors, iterates identical to the clean run."""
    rng = np.random.default_rng(10)
    bsz, n = 2, 5
    t = rng.normal(size=(bsz, n))
    eye = np.eye(n)

    def grad_hess(x):
        d = x - t
        return d + d ** 3, (1.0 + 3.0 * d ** 2)[:, :, None] * eye

    cfg = BatchedNewtonConfig(method="analog_2n", tol=1e-9, max_iter=30)
    clean_svc = SolveService(batch_slots=4)
    tr_clean = newton_batch(
        grad_hess, np.zeros((bsz, n)), cfg,
        rounds=clean_svc.session(method="analog_2n"),
    )

    inj = FaultInjector(FaultPlan(schedule=((1, "device_fault"),)))
    svc = SolveService(batch_slots=4, fault_injector=inj)
    tr = newton_batch(
        grad_hess, np.zeros((bsz, n)), cfg,
        rounds=svc.session(method="analog_2n"),
    )
    st = svc.stats
    assert st["fault_injections"] >= 1
    assert st["retries"] + st["bisections"] >= 1
    assert st["errors"] == {k: 0 for k in st["errors"]}
    assert tr.converged.all()
    assert np.array_equal(tr.iterations, tr_clean.iterations)
    assert np.abs(tr.x - tr_clean.x).max() <= 1e-12


# --------------------------------------------------------- FEM mesh stream
def test_fem_stream_through_service_parity():
    from repro.data.fem import mesh_stream

    meshes = list(mesh_stream(0, 10, grids=((4, 4), (5, 5), (6, 6))))
    svc = SolveService(batch_slots=4)
    rids = [svc.submit(m.a, m.b, method="analog_2n") for m in meshes]
    out = svc.drain()
    for rid, m in zip(rids, meshes):
        ref = solve(m.a, m.b, method="analog_2n")
        assert np.abs(out[rid].x - ref.x).max() <= PARITY_ATOL
    st = svc.stats
    assert st["requests"] == len(meshes)
    # one pattern per bucket: the fixed sparsity class per grid size
    assert all(
        b["pattern_derivations"] == 1 for b in st["buckets"].values()
    )
