"""Distributed runtime: rules, straggler mitigation, elastic planning,
and (in a subprocess with forced host devices) a real sharded train
step + elastic re-shard on a debug mesh."""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed.rules import adjust_batch_rule, batch_axis_for, make_rules
from repro.distributed.elastic import grad_accum_factor, plan_mesh
from repro.distributed.straggler import StragglerConfig, StragglerTracker


# ------------------------------------------------------------------ rules
def test_rules_heads_mode():
    r = make_rules(get_config("qwen3_8b"))           # 32 heads % 16 == 0
    assert r["q_heads"] == "model" and r["head_dim"] is None


def test_rules_dim_mode_for_odd_heads():
    r = make_rules(get_config("yi_34b"))             # 56 heads, dh=128
    assert r["q_heads"] is None and r["head_dim"] == "model"


def test_rules_decode_mode_shards_head_dim():
    r = make_rules(get_config("command_r_35b"), job="decode")
    assert r["head_dim"] == "model" and r["kv_heads"] is None


def test_rules_ep_for_granite_moe():
    r = make_rules(get_config("granite_moe_1b_a400m"))
    assert r["expert"] == "model"
    r2 = make_rules(get_config("mixtral_8x22b"))
    assert r2["expert"] is None and r2["ff"] == "model"


def test_batch_axis_shrinks_for_tiny_batch():
    assert batch_axis_for(256, False) == "data"
    assert batch_axis_for(1, False) is None
    assert batch_axis_for(256, True) == ("pod", "data")
    assert batch_axis_for(2, True) == "pod"


# -------------------------------------------------------------- straggler
def test_straggler_detection_and_reassignment():
    tr = StragglerTracker(4, StragglerConfig(min_samples=4, k_dev=2.0))
    for step in range(10):
        for w in range(4):
            tr.observe(w, 1.0 if w != 3 else 3.0)
    assert tr.stragglers() == [3]
    mb = {0: [0, 1], 1: [2, 3], 2: [4, 5], 3: [6, 7]}
    out = tr.reassign(mb)
    assert len(out[3]) == 1                       # shed load
    total = sorted(sum(out.values(), []))
    assert total == list(range(8))                # batch preserved


def test_straggler_eviction_streak():
    cfg = StragglerConfig(min_samples=2, k_dev=1.5, evict_after=3)
    tr = StragglerTracker(2, cfg)
    for _ in range(10):
        tr.observe(0, 1.0)
        tr.observe(1, 5.0)
        tr.stragglers()
    assert tr.to_evict() == [1]


# ---------------------------------------------------------------- elastic
def test_plan_mesh_degrades_gracefully():
    assert plan_mesh(512).n_devices == 512
    assert plan_mesh(511).n_devices == 256
    p = plan_mesh(100)
    assert p.n_devices <= 100
    assert plan_mesh(1).n_devices == 1
    with pytest.raises(RuntimeError):
        plan_mesh(0)


def test_grad_accum_keeps_global_batch():
    assert grad_accum_factor(256, 16, 8, 2) == 16
    assert grad_accum_factor(256, 16, 16, 2) == 8


# ------------------------------------------------- subprocess integration
_SUBPROCESS_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, json
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.distributed.rules import make_rules, adjust_batch_rule
    from repro.distributed.sharding import use_rules, param_specs
    from repro.launch.mesh import make_debug_mesh, mesh_context
    from repro.models.model import init_params, param_logical_axes
    from repro.optim.adamw import adamw
    from repro.training.step import init_train_state, make_train_step
    from repro.distributed.elastic import plan_mesh, reshard_state
    from jax.sharding import PartitionSpec as P

    cfg = get_smoke_config("qwen3_8b")
    mesh = make_debug_mesh((2, 4), ("data", "model"))
    rules = {**make_rules(cfg, model_axis=4), "batch": "data"}
    # smoke dims: 4 heads % 4 == 0 -> heads mode on the debug mesh
    opt = adamw(1e-3)

    # jax 0.4.x jit only accepts Sharding objects in in_shardings;
    # wrap the PartitionSpec trees in NamedSharding (works on both
    # API generations).  P is a tuple subclass -> needs is_leaf.
    from jax.sharding import NamedSharding as NS
    def shard_tree(tree, m):
        return jax.tree.map(lambda s: NS(m, s), tree,
                            is_leaf=lambda x: isinstance(x, P))

    with mesh_context(mesh), use_rules(rules):
        state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
        p_specs = param_specs(param_logical_axes(cfg), rules)
        specs = {
            "params": p_specs,
            "opt_state": {"mu": p_specs, "nu": p_specs, "step": P()},
            "step": P(),
        }
        specs = shard_tree(specs, mesh)
        # place concrete arrays on the mesh per the specs (jit
        # in_shardings must match committed array shardings)
        la = param_logical_axes(cfg)
        state = {
            "params": reshard_state(state["params"], la, mesh, rules),
            "opt_state": {
                "mu": reshard_state(state["opt_state"]["mu"], la, mesh, rules),
                "nu": reshard_state(state["opt_state"]["nu"], la, mesh, rules),
                "step": state["opt_state"]["step"],
            },
            "step": state["step"],
        }
        batch_specs = shard_tree({"tokens": P("data", None),
                                  "targets": P("data", None)}, mesh)
        step = jax.jit(make_train_step(cfg, opt),
                       in_shardings=(specs, batch_specs),
                       out_shardings=(specs, NS(mesh, P())))
        from jax.sharding import NamedSharding
        toks = jax.device_put(
            jnp.zeros((4, 32), jnp.int32) + 3,
            NamedSharding(mesh, P("data", None)))
        batch = {"tokens": toks, "targets": toks}
        state, metrics = step(state, batch)
        state, metrics = step(state, batch)
        loss = float(metrics["loss"])
        assert np.isfinite(loss)

        # --- elastic: shrink to 4 devices, re-shard, keep training ---
        plan = plan_mesh(4)
        assert plan.n_devices <= 4
        mesh2 = make_debug_mesh((2, 2), ("data", "model"))
        rules2 = {**make_rules(cfg, model_axis=2), "batch": "data"}
    with mesh_context(mesh2), use_rules(rules2):
        from jax.sharding import NamedSharding as NS
        rep2 = NS(mesh2, P())
        state2 = {
            "params": reshard_state(
                state["params"], param_logical_axes(cfg), mesh2, rules2),
            "opt_state": {
                "mu": reshard_state(state["opt_state"]["mu"],
                                    param_logical_axes(cfg), mesh2, rules2),
                "nu": reshard_state(state["opt_state"]["nu"],
                                    param_logical_axes(cfg), mesh2, rules2),
                "step": jax.device_put(state["opt_state"]["step"], rep2),
            },
            "step": jax.device_put(state["step"], rep2),
        }
        from jax.sharding import NamedSharding
        toks2 = jax.device_put(
            jnp.zeros((4, 32), jnp.int32) + 3,
            NamedSharding(mesh2, P("data", None)))
        batch2 = {"tokens": toks2, "targets": toks2}
        step2 = jax.jit(make_train_step(cfg, opt))
        state2, metrics2 = step2(state2, batch2)
        loss2 = float(metrics2["loss"])
        assert np.isfinite(loss2)
    print(json.dumps({"loss": loss, "loss2": loss2}))
""")


@pytest.mark.slow
def test_sharded_train_step_and_elastic_reshard():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_PROG],
        capture_output=True, text=True, env=env, timeout=600,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert out.returncode == 0, out.stderr[-4000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert np.isfinite(res["loss"]) and np.isfinite(res["loss2"])
