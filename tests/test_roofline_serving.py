"""Roofline HLO parser correctness + serving engine behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import (
    HW,
    collective_bytes_from_hlo,
    roofline_report,
)
from repro.roofline.hlo_parse import loop_aware_costs


def test_parser_scan_trip_multiplication():
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        return jax.lax.scan(body, x, w)[0]

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
        jax.ShapeDtypeStruct((7, 128, 128), jnp.float32)).compile()
    got = loop_aware_costs(c.as_text())
    assert got["flops"] == pytest.approx(2 * 128 ** 3 * 7, rel=0.01)


def test_parser_nested_scan():
    def f(x, w):
        def outer(c, wg):
            def inner(ci, wi):
                return ci @ wi, None
            return jax.lax.scan(inner, c, wg)[0], None
        return jax.lax.scan(outer, x, w)[0]

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((3, 5, 64, 64), jnp.float32)).compile()
    got = loop_aware_costs(c.as_text())
    assert got["flops"] == pytest.approx(2 * 64 ** 3 * 15, rel=0.01)


def test_parser_dus_counts_region_only():
    def step(cache, tok):
        return jax.lax.dynamic_update_slice(cache, tok, (0, 0, 0))

    c = jax.jit(step).lower(
        jax.ShapeDtypeStruct((64, 1024, 128), jnp.bfloat16),
        jax.ShapeDtypeStruct((64, 1, 128), jnp.bfloat16)).compile()
    got = loop_aware_costs(c.as_text())
    # in-place model: the DUS itself contributes only the update region;
    # the remaining traffic is the (donation-removable) entry/exit copy
    # of the buffer — well below the naive 2x read+write of the buffer
    # per update (~67 MB)
    assert got["bytes"] < 36e6


def test_roofline_report_terms():
    r = roofline_report(flops=197e12, bytes_accessed=819e9,
                        collective_bytes=50e9, n_chips=256,
                        model_flops=197e12 * 256 * 0.5)
    assert r["compute_s"] == pytest.approx(1.0)
    assert r["memory_s"] == pytest.approx(1.0)
    assert r["collective_s"] == pytest.approx(1.0)
    assert r["mfu_upper_bound"] == pytest.approx(0.5)


def test_collective_regex():
    hlo = """
  %ar = bf16[1024,512]{1,0} all-reduce(%x), replica_groups={}
  %ag = f32[64]{0} all-gather(%y), dimensions={0}
  %cp = f32[8,8]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
"""
    got = collective_bytes_from_hlo(hlo)
    assert got["all-reduce"] == 1024 * 512 * 2
    assert got["all-gather"] == 64 * 4
    assert got["collective-permute"] == 64 * 4
    assert got["total"] == sum(
        got[k] for k in ("all-reduce", "all-gather", "collective-permute"))


# ---------------------------------------------------------------- serving
def test_serve_engine_generates():
    from repro.configs import get_smoke_config
    from repro.models.model import init_params
    from repro.serving.engine import Request, ServeEngine

    cfg = get_smoke_config("qwen3_8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=2, max_seq=64)
    reqs = [Request(rid=i, prompt=np.arange(5 + i) % cfg.vocab, max_new=6)
            for i in range(4)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=200)
    for r in reqs:
        assert r.done and len(r.out) >= 6
        assert all(0 <= t < cfg.vocab_padded for t in r.out)


def test_serve_engine_slot_recycling():
    from repro.configs import get_smoke_config
    from repro.models.model import init_params
    from repro.serving.engine import Request, ServeEngine

    cfg = get_smoke_config("mamba2_370m")
    params = init_params(cfg, jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, batch_slots=1, max_seq=48)
    reqs = [Request(rid=i, prompt=np.arange(4), max_new=4) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=100)
    assert all(r.done for r in reqs)


def test_serve_engine_staggered_prompts_match_sequential():
    """Regression: step() used to collapse per-slot positions to a
    single max(pos), so after a mid-stream admit a lagging slot wrote
    its KV rows at the leading slot's position (and took its rotary
    phase).  Staggered-length prompts decoded in a shared batch must
    produce exactly the tokens of one-at-a-time single-slot decoding."""
    from repro.configs import get_smoke_config
    from repro.models.model import init_params
    from repro.serving.engine import Request, ServeEngine

    cfg = get_smoke_config("qwen3_8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    # lengths 3/9/4: slots start staggered AND the third request is
    # admitted mid-stream into whichever slot frees first
    prompts = [np.arange(3) % cfg.vocab, (np.arange(9) * 7) % cfg.vocab,
               (np.arange(4) * 3) % cfg.vocab]

    def run(slots):
        eng = ServeEngine(cfg, params, batch_slots=slots, max_seq=64)
        reqs = [Request(rid=i, prompt=p, max_new=6)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run(max_steps=300)
        assert all(r.done for r in reqs)
        return [r.out for r in reqs]

    assert run(2) == run(1)


# ------------------------------------------------------- slot admission
def test_admission_queue_ordering():
    """Priority first, EDF within a class (deadline=None ranks last),
    FIFO ties — the ordering both serving front-ends share."""
    from repro.serving.engine import AdmissionQueue, Request

    q = AdmissionQueue()
    fifo1 = q.push(Request(rid=0, prompt=np.arange(2)))
    late = q.push(Request(rid=1, prompt=np.arange(2)), deadline=2.0)
    soon = q.push(Request(rid=2, prompt=np.arange(2)), deadline=1.0)
    hi = q.push(Request(rid=3, prompt=np.arange(2)),
                priority=1, deadline=9.0)
    fifo2 = q.push(Request(rid=4, prompt=np.arange(2)))
    assert [q.pop() for _ in range(len(q))] == [hi, soon, late, fifo1, fifo2]
    with pytest.raises(IndexError):
        q.pop()


def test_admission_queue_requeue_keeps_original_rank():
    """requeue() re-admits items with their original stamps: a replayed
    drain pops in the same order, and newer arrivals don't overtake a
    re-queued high-priority item."""
    from repro.serving.engine import AdmissionQueue, Request

    q = AdmissionQueue()
    first = q.push(Request(rid=0, prompt=np.arange(2)), priority=2)
    second = q.push(Request(rid=1, prompt=np.arange(2)))
    drained = q.pop_all()
    assert drained == [first, second] and not q
    q.requeue(drained)
    newcomer = q.push(Request(rid=2, prompt=np.arange(2)))
    assert q.pop_all() == [first, second, newcomer]
    dropped = q.discard(lambda r: r.rid == 1)
    assert dropped == [] and len(q) == 0


def test_serve_engine_priority_admission():
    """A saturated engine admits the high-priority request into the
    first freed slot ahead of earlier FIFO arrivals."""
    from repro.configs import get_smoke_config
    from repro.models.model import init_params
    from repro.serving.engine import Request, ServeEngine

    cfg = get_smoke_config("mamba2_370m")
    params = init_params(cfg, jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, batch_slots=1, max_seq=48)
    reqs = [Request(rid=i, prompt=np.arange(4), max_new=3) for i in range(3)]
    eng.submit(reqs[0])
    eng.submit(reqs[1])
    eng.submit(reqs[2], priority=1)

    admitted = []
    orig = eng._prefill_slot

    def spy(slot, req):
        admitted.append(req.rid)
        return orig(slot, req)

    eng._prefill_slot = spy
    eng.run(max_steps=100)
    assert all(r.done for r in reqs)
    # admission happens at step time, so the priority-1 request takes
    # the slot first; the FIFO arrivals follow in order
    assert admitted == [2, 0, 1]
