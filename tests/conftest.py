"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see ONE device; multi-device tests run in subprocesses that set
--xla_force_host_platform_device_count themselves."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def small_spd(seed=0, n=10):
    from repro.data.spd import random_spd, random_rhs_from_solution

    r = np.random.default_rng(seed)
    a = random_spd(r, n)
    x, b = random_rhs_from_solution(r, a)
    return a, x, b
