"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret mode executes the Pallas kernel body on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import crosspoint_mvm, spd_transform_arrays, transient_step


SHAPES_MVM = [
    (16, 16, 1), (100, 100, 1), (128, 128, 128), (257, 130, 5), (300, 513, 64),
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dt):
    # f32 headroom for a k=513 dot: BLAS accumulation order varies with
    # the host's thread count, and the worst element lands just above
    # 2e-5 on single-core runners
    return dict(rtol=2e-2, atol=2e-2) if dt == jnp.bfloat16 else dict(rtol=5e-5, atol=5e-5)


@pytest.mark.parametrize("m,k,b", SHAPES_MVM)
@pytest.mark.parametrize("dt", DTYPES)
def test_crosspoint_mvm_sweep(m, k, b, dt):
    rng = np.random.default_rng(m * 7 + k)
    g = jnp.asarray(rng.standard_normal((m, k)), dt)
    v = jnp.asarray(rng.standard_normal((k, b)), dt)
    out = crosspoint_mvm(g, v, interpret=True)
    want = ref.crosspoint_mvm_ref(g, v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **_tol(dt))


def test_crosspoint_mvm_vector_input():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((50, 50)), jnp.float32)
    v = jnp.asarray(rng.standard_normal(50), jnp.float32)
    out = crosspoint_mvm(g, v, interpret=True)
    assert out.shape == (50,)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g) @ np.asarray(v),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n,b", [(64, 1), (200, 3), (256, 128), (130, 17)])
@pytest.mark.parametrize("dt", DTYPES)
def test_transient_step_sweep(n, b, dt):
    rng = np.random.default_rng(n + b)
    m = jnp.asarray(rng.standard_normal((n, n)) * 0.1, dt)
    z = jnp.asarray(rng.standard_normal((n, b)), dt)
    c = jnp.asarray(rng.standard_normal((n, b)), dt)
    out = transient_step(m, z, c, 1e-2, interpret=True)
    want = ref.transient_step_ref(m, z, c, 1e-2)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **_tol(dt))


def test_transient_step_iterates_to_fixed_point():
    """Scanning the kernel step converges to the linear solve (the
    'physics does the iteration' path)."""
    rng = np.random.default_rng(3)
    n = 32
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    lam = rng.uniform(0.5, 2.0, n)
    a = (q * lam) @ q.T
    x_true = rng.standard_normal(n)
    b = a @ x_true
    m = jnp.asarray(-a, jnp.float32)
    c = jnp.asarray(b, jnp.float32)[:, None]
    z = jnp.zeros((n, 1), jnp.float32)
    dt = 0.5 / lam.max()
    for _ in range(400):
        z = transient_step(m, z, c, dt, interpret=True)
    np.testing.assert_allclose(np.asarray(z[:, 0]), x_true, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("n", [16, 100, 128, 200])
@pytest.mark.parametrize("dt", [jnp.float32])
def test_spd_transform_sweep(n, dt):
    from repro.core.transform import transform_2n
    from repro.data.spd import random_spd, random_rhs_from_solution

    rng = np.random.default_rng(n)
    a = random_spd(rng, n)
    x, b = random_rhs_from_solution(rng, a)
    ka, kb, d, ks = spd_transform_arrays(
        jnp.asarray(a, dt), jnp.asarray(b, dt), interpret=True)
    tr = transform_2n(a, b)
    scale = float(np.abs(np.asarray(tr.k_a)).max())
    np.testing.assert_allclose(np.asarray(ka), np.asarray(tr.k_a, np.float32),
                               atol=1e-5 * scale)
    np.testing.assert_allclose(np.asarray(kb), np.asarray(tr.k_b, np.float32),
                               atol=1e-5 * scale)
    np.testing.assert_allclose(np.asarray(d), np.asarray(tr.d, np.float32),
                               atol=1e-5 * scale)


def test_spd_transform_solution_roundtrip():
    """Kernel-produced K_A/K_B solve back to x (end-to-end fusion check)."""
    from repro.data.spd import random_spd, random_rhs_from_solution

    rng = np.random.default_rng(9)
    n = 60
    a = random_spd(rng, n) * 1e6   # scale to O(1) for f32 conditioning
    x, b = random_rhs_from_solution(rng, a)
    ka, kb, d, ks = spd_transform_arrays(
        jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32), interpret=True)
    m = np.block([[np.asarray(ka) + np.diag(np.asarray(ks)), np.asarray(kb)],
                  [np.asarray(kb), np.asarray(ka) + np.diag(np.asarray(ks))]])
    rhs = np.concatenate([b, -b])
    y = np.linalg.solve(m.astype(np.float64), rhs)
    np.testing.assert_allclose(y[:n], x, rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# Pallas flash attention (the §Perf roofline-driven kernel)
# ---------------------------------------------------------------------------

def _naive_attn(q, k, v, causal, window=0):
    b, s, h, d = q.shape
    _, t, kv, _ = k.shape
    g = h // kv
    qg = q.reshape(b, s, kv, g, d)
    sc = np.einsum("bqhgd,bkhd->bhgqk", qg, k) / np.sqrt(d)
    mask = np.ones((s, t), bool)
    if causal:
        mask &= np.arange(t)[None, :] <= np.arange(s)[:, None]
    if window:
        mask &= np.arange(t)[None, :] > np.arange(s)[:, None] - window
    sc = np.where(mask[None, None, None], sc, -1e30)
    p = np.exp(sc - sc.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return np.einsum("bhgqk,bkhd->bqhgd", p, v).reshape(b, s, h, d)


@pytest.mark.parametrize("s,h,kv,d,causal,window", [
    (128, 4, 2, 32, True, 0),
    (128, 4, 4, 32, False, 0),
    (192, 8, 2, 16, True, 64),
    (100, 4, 1, 32, True, 0),       # ragged
])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_flash_attention_pallas_sweep(s, h, kv, d, causal, window, dt):
    from repro.kernels.flash_attention import flash_attention_pallas

    rng = np.random.default_rng(s + h)
    q = rng.standard_normal((2, s, h, d)).astype(np.float32)
    k = rng.standard_normal((2, s, kv, d)).astype(np.float32)
    v = rng.standard_normal((2, s, kv, d)).astype(np.float32)
    out = flash_attention_pallas(
        jnp.asarray(q, dt), jnp.asarray(k, dt), jnp.asarray(v, dt),
        causal=causal, window=window, q_block=64, kv_block=64,
        interpret=True)
    want = _naive_attn(q, k, v, causal, window)
    tol = 3e-2 if dt == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), want, rtol=tol, atol=tol)


def test_flash_attention_pallas_matches_jnp_flash():
    """Kernel vs the framework's pure-JAX flash (the production pair)."""
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.models.attention import flash_attention

    rng = np.random.default_rng(11)
    q = jnp.asarray(rng.standard_normal((2, 96, 6, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 96, 3, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 96, 3, 32)), jnp.float32)
    a = flash_attention_pallas(q, k, v, causal=True, q_block=32,
                               kv_block=32, interpret=True)
    b = flash_attention(q, k, v, causal=True, q_block=32, kv_block=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)
