"""Checkpoint manager (atomic/async/keep-K/resume) + data pipeline."""

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.tokens import SyntheticTokens


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 8)),
                   "b": jnp.zeros((8,), jnp.bfloat16)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    state = _state()
    mgr.save(7, state, data_state={"index": 42, "seed": 0,
                                   "host_index": 0, "host_count": 1})
    like = jax.eval_shape(lambda: _state())
    restored, ds = mgr.restore(7, like)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    assert restored["params"]["b"].dtype == np.asarray(state["params"]["b"]).dtype
    assert ds["index"] == 42


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state())
    assert mgr.all_steps() == [3, 4]


def test_async_save_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=True)
    mgr.save(5, _state())
    mgr.wait()
    assert mgr.latest_step() == 5
    step, restored, _ = mgr.restore_latest(jax.eval_shape(lambda: _state()))
    assert step == 5


def test_atomicity_no_torn_checkpoint(tmp_path):
    """A .tmp directory must never be discoverable as a checkpoint."""
    mgr = CheckpointManager(tmp_path, async_save=False)
    mgr.save(1, _state())
    tmp = tmp_path / "step_00000009.tmp"
    tmp.mkdir()
    (tmp / "manifest.json").write_text("{}")
    assert mgr.all_steps() == [1]


def test_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    mgr.save(1, _state())
    bad_like = {"params": {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32),
                           "b": jax.ShapeDtypeStruct((8,), jnp.bfloat16)},
                "step": jax.ShapeDtypeStruct((), jnp.int32)}
    with pytest.raises(ValueError, match="shape mismatch"):
        mgr.restore(1, bad_like)


# ------------------------------------------------------------------ data
def test_data_deterministic():
    d1 = SyntheticTokens(vocab=100, seq_len=16, batch_size=4, seed=3)
    d2 = SyntheticTokens(vocab=100, seq_len=16, batch_size=4, seed=3)
    b1, b2 = next(d1), next(d2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    d1.close(); d2.close()


def test_data_resume_from_state():
    d = SyntheticTokens(vocab=100, seq_len=16, batch_size=4, seed=5)
    next(d); next(d)
    st = d.state()
    b3 = next(d)
    d.close()
    d2 = SyntheticTokens.from_state(st, vocab=100, seq_len=16, batch_size=4)
    b3b = next(d2)
    d2.close()
    np.testing.assert_array_equal(b3["tokens"], b3b["tokens"])


def test_data_host_sharding_disjoint():
    a = SyntheticTokens(vocab=100, seq_len=16, batch_size=4, seed=1,
                        host_index=0, host_count=2)
    b = SyntheticTokens(vocab=100, seq_len=16, batch_size=4, seed=1,
                        host_index=1, host_count=2)
    ba, bb = next(a), next(b)
    assert not np.array_equal(ba["tokens"], bb["tokens"])
    a.close(); b.close()


def test_data_targets_shifted():
    d = SyntheticTokens(vocab=100, seq_len=16, batch_size=2, seed=1)
    b = next(d)
    d.close()
    assert b["tokens"].shape == (2, 16)
    assert b["targets"].shape == (2, 16)
    assert b["tokens"].dtype == np.int32
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 100


def test_data_learnable_structure():
    """The Markov component makes next-token prediction beatable:
    P(correct | follow-rule) ~ 0.5 >> uniform 1/vocab."""
    d = SyntheticTokens(vocab=1000, seq_len=256, batch_size=8, seed=2)
    b = next(d)
    d.close()
    toks, tgt = b["tokens"], b["targets"]
    shift = d._shift
    pred = (toks + shift[toks % 997]) % 1000
    hit = (pred == tgt).mean()
    assert hit > 0.2, hit   # >> uniform 1/vocab = 0.001
